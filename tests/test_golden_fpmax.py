"""Golden regression tests: the four fabricated FPMax units vs the paper's
Table I / Table II silicon numbers.

Tolerance derivation (the "stated tolerances" of these goldens):

  * ANCHOR_RTOL = 1e-6 — anchored mode applies per-design multiplicative
    corrections computed *from* the Table I rows, so freq / leak / total
    power / area are exact at the measured operating points by construction;
    the tolerance only absorbs float round-trips.
  * QUOTE_RTOL = 0.05 — Table I's GFLOPS/W and GFLOPS/mm^2 are quoted
    normalized and rounded to 3 significant digits, and are not exactly
    self-consistent with the quoted freq/power/area (recomputing
    2f/P from the table's own numbers lands within ~4%% of the quoted
    efficiency for sp_fma).  5%% bounds the quoting slack without masking a
    real model regression.
  * DELAY_RTOL = 0.30 — the SPEC-like mixture is calibrated to Fig. 2(c)'s
    *relative* penalty reductions (37%% / 57%%), not to absolute delays; the
    resulting absolute average delays land 9-22%% below the Table I
    normalized delays across all four units.  30%% pins that envelope.
  * Global-fit (non-anchored) residual envelope: measured on the seed
    calibration — freq within 29%%, total power within 12%%, area within
    30%%, efficiencies within 18%% (GFLOPS/W) / 45%% (GFLOPS/mm^2).  The
    bounds below add a small margin so a *worse* fit fails while optimizer
    jitter does not.
"""
import numpy as np
import pytest

from repro.core.dse import sweep_arrays
from repro.core.energy_model import (calibrate, calibration_report, predict,
                                     predict_points)
from repro.core.fpu_arch import FABRICATED, TABLE_I
from repro.core.latency_sim import calibrated_spec_mix

ANCHOR_RTOL = 1e-6
QUOTE_RTOL = 0.05
DELAY_RTOL = 0.30


@pytest.fixture(scope="module")
def params():
    return calibrate()


@pytest.fixture(scope="module")
def mix():
    return calibrated_spec_mix()


@pytest.mark.parametrize("name", sorted(FABRICATED))
def test_anchored_point_predictions_are_silicon_exact(params, name):
    d, m = FABRICATED[name], TABLE_I[name]
    p = predict(d, params, vdd=m.vdd, vbb=m.vbb, anchored=True)
    np.testing.assert_allclose(p["freq_ghz"], m.freq_ghz, rtol=ANCHOR_RTOL)
    np.testing.assert_allclose(p["p_leak_mw"], m.leak_mw, rtol=ANCHOR_RTOL)
    np.testing.assert_allclose(p["p_total_mw"], m.power_mw, rtol=ANCHOR_RTOL)
    np.testing.assert_allclose(p["area_mm2"], m.area_mm2, rtol=ANCHOR_RTOL)


@pytest.mark.parametrize("name", sorted(FABRICATED))
def test_anchored_efficiencies_match_table1_quotes(params, name):
    d, m = FABRICATED[name], TABLE_I[name]
    p = predict(d, params, vdd=m.vdd, vbb=m.vbb, anchored=True)
    np.testing.assert_allclose(p["gflops_per_w"], m.gflops_per_w,
                               rtol=QUOTE_RTOL)
    np.testing.assert_allclose(p["gflops_per_mm2"], m.gflops_per_mm2,
                               rtol=QUOTE_RTOL)


def test_anchored_sweep_rows_pin_table1(params, mix):
    """The SweepResult pipeline (not just scalar predict) reproduces the
    silicon: sweep the four units over grids containing their measured
    operating points with the calibrated mixture and check every Table I
    row — efficiencies at quote tolerance, average benchmarked delay vs the
    table's normalized delay at the mixture-calibration tolerance."""
    designs = list(FABRICATED.values())
    vdds = sorted({TABLE_I[d.name].vdd for d in designs})
    res = sweep_arrays(designs, params, np.asarray(vdds), np.asarray([1.2]),
                       mix=mix, with_latency=True, anchored=True)
    for i, d in enumerate(designs):
        m = TABLE_I[d.name]
        rows = np.nonzero((res.design_index == i) & (res.vdd == m.vdd)
                          & (res.vbb == 1.2))[0]
        assert rows.size == 1, d.name
        r = int(rows[0])
        np.testing.assert_allclose(res.metrics["freq_ghz"][r], m.freq_ghz,
                                   rtol=ANCHOR_RTOL, err_msg=d.name)
        np.testing.assert_allclose(res.metrics["gflops_per_w"][r],
                                   m.gflops_per_w, rtol=QUOTE_RTOL,
                                   err_msg=d.name)
        np.testing.assert_allclose(res.metrics["gflops_per_mm2"][r],
                                   m.gflops_per_mm2, rtol=QUOTE_RTOL,
                                   err_msg=d.name)
        np.testing.assert_allclose(res.metrics["avg_delay_ns"][r],
                                   m.norm_delay_ns, rtol=DELAY_RTOL,
                                   err_msg=d.name)


def test_global_fit_residuals_within_stated_envelope(params):
    rep = calibration_report(params)
    for name, row in rep.items():
        assert abs(row["freq_rel_err"]) <= 0.32, (name, row)
        assert abs(row["power_rel_err"]) <= 0.15, (name, row)
        assert abs(row["area_rel_err"]) <= 0.33, (name, row)
        gw = row["gflops_per_w_pred"] / row["gflops_per_w_meas"] - 1.0
        gm = row["gflops_per_mm2_pred"] / row["gflops_per_mm2_meas"] - 1.0
        assert abs(gw) <= 0.20, (name, gw)
        assert abs(gm) <= 0.48, (name, gm)


def test_table2_sp_fma_row_anchored(params):
    """Table II quotes our SP FMA at 217 GFLOPS/mm^2 / 106 GFLOPS/W; the
    anchored batched path must land on the quoted row."""
    d = FABRICATED["sp_fma"]
    m = TABLE_I["sp_fma"]
    p = predict_points([d], params, vdd=[m.vdd], vbb=[m.vbb], anchored=True)
    np.testing.assert_allclose(p["gflops_per_mm2"][0], 217.0,
                               rtol=QUOTE_RTOL)
    np.testing.assert_allclose(p["gflops_per_w"][0], 106.0, rtol=QUOTE_RTOL)


def test_anchored_sweep_matches_scalar_predict(params):
    """Anchoring through sweep_arrays must agree with the scalar anchored
    predict path at every grid point (plumbing golden, tight tolerance)."""
    designs = list(FABRICATED.values())
    vdd = np.asarray([0.8, 0.9])
    vbb = np.asarray([0.0, 1.2])
    res = sweep_arrays(designs, params, vdd, vbb, anchored=True)
    for r in range(len(res)):
        d = res.design_of(r)
        ref = predict(d, params, vdd=float(res.vdd[r]),
                      vbb=float(res.vbb[r]), anchored=True)
        for k in ("freq_ghz", "p_total_mw", "area_mm2", "gflops_per_w",
                  "gflops_per_mm2"):
            np.testing.assert_allclose(res.metrics[k][r], ref[k],
                                       rtol=1e-9, err_msg=(d.name, k))
