"""Device-resident serving hot path: fused multi-token decode must be
bitwise-identical to per-sample greedy decoding across prompt-length
buckets and model families, bucketed prefill must share compiled programs,
bulk (dispatch-boundary) energy charging must match the seed per-token
accounting, run() must collect finished work, and chip-aware admission
must route requests to per-unit fleets."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import chip
from repro.core.energy_model import calibrate
from repro.models import LM
from repro.serve.engine import (BatchedServer, ReferenceServer, Request,
                                bucket_length, greedy_decode)

from helpers import FakeClock


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = LM(cfg)
    return cfg, model, model.init(jax.random.key(3))


def _prompts(cfg, lens, seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


# ------------------------------------------------------------- equivalence
def test_bucket_length():
    assert [bucket_length(n) for n in (1, 8, 9, 16, 17, 100)] == \
        [8, 8, 16, 16, 32, 128]


def test_fused_decode_bitwise_matches_greedy_across_buckets(dense):
    """Prompt lengths spanning three pad buckets, more requests than slots
    (churn), multi-token dispatches: every output must equal the
    single-sequence reference decoder token for token."""
    cfg, model, params = dense
    prompts = _prompts(cfg, (3, 8, 9, 15, 17, 30))
    refs = [greedy_decode(model, params, p, 7, max_len=64) for p in prompts]
    server = BatchedServer(model, params, slots=4, max_len=64,
                           dispatch_tokens=3)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=7)
            for i, p in enumerate(prompts)]
    for r in reqs:
        server.submit(r)
    finished = server.run(max_steps=100)
    assert sorted(r.uid for r in finished) == list(range(len(reqs)))
    for r, ref in zip(reqs, refs):
        assert r.output == ref, (r.uid, r.output, ref)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "falcon-mamba-7b",
                                  "zamba2-1.2b"])
def test_fused_decode_matches_greedy_other_families(arch):
    """Sliding-window ring caches (incl. a prompt longer than the window)
    and exact-length SSM/hybrid batching through the fused path."""
    cfg = get_config(arch).reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(5))
    lens = (5, 20, 7) if cfg.window else (5, 7, 7)
    prompts = _prompts(cfg, lens)
    refs = [greedy_decode(model, params, p, 5, max_len=48) for p in prompts]
    server = BatchedServer(model, params, slots=2, max_len=48,
                           dispatch_tokens=4)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        server.submit(r)
    server.run(max_steps=100)
    for r, ref in zip(reqs, refs):
        assert r.output == ref, (r.uid, r.output, ref)


def test_cache_capped_request_finishes_at_dispatch_boundary(dense):
    """A request whose budget was capped by the cache capacity is finished
    the moment its device budget drains — no extra dead dispatch — and is
    marked done (truncated), not expired."""
    cfg, model, params = dense
    server = BatchedServer(model, params, slots=1, max_len=32,
                           dispatch_tokens=4)
    req = Request(uid=0, prompt=_prompts(cfg, (20,))[0], max_new_tokens=50)
    server.submit(req)
    steps = 0
    for _ in range(20):
        if server.step(4) == 0 and not any(server._queues.values()):
            break
        steps += 1
    assert req.done and not req.expired
    assert len(req.output) == 1 + (32 - 20)  # prefill token + capped budget
    assert steps == 3  # ceil(12 / 4) dispatches, none wasted


def test_run_returns_finished_and_expired_requests(dense):
    """Regression: run() used to return an always-empty list."""
    cfg, model, params = dense
    clock = FakeClock(0.0)
    server = BatchedServer(model, params, slots=2, max_len=32, clock=clock)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=3)
            for i, p in enumerate(_prompts(cfg, (4, 5, 6)))]
    reqs.append(Request(uid=3, prompt=_prompts(cfg, (4,))[0],
                        max_new_tokens=3, deadline_s=-1.0))  # expires queued
    for r in reqs:
        server.submit(r)
    finished = server.run(max_steps=50)
    assert sorted(r.uid for r in finished) == [0, 1, 2, 3]
    assert all(r.done for r in finished)
    assert [r.uid for r in finished if r.expired] == [3]
    # a second run has nothing new to report
    assert server.run(max_steps=5) == []


def test_bucketed_prefill_shares_compiled_programs(dense):
    """Two admission waves with different prompt lengths in the same
    power-of-two bucket must reuse one compiled prefill program."""
    cfg, model, params = dense
    from repro.serve import engine as eng
    server = BatchedServer(model, params, slots=2, max_len=64)
    base = eng._admit_jit._cache_size()
    for wave, lens in enumerate(((9, 11), (13, 16))):  # all bucket 16
        reqs = [Request(uid=10 * wave + i, prompt=p, max_new_tokens=2)
                for i, p in enumerate(_prompts(cfg, lens))]
        for r in reqs:
            server.submit(r)
        server.run(max_steps=20)
        assert all(r.done for r in reqs)
    assert eng._admit_jit._cache_size() - base == 1


def test_slot_churn_under_mixed_deadlines(dense):
    """Expiring and surviving requests interleave through the same slots;
    survivors' outputs stay bitwise-correct and every slot is recycled."""
    cfg, model, params = dense
    prompts = _prompts(cfg, (4, 6, 5, 7, 9, 8))
    clock = FakeClock(0.0)
    server = BatchedServer(model, params, slots=2, max_len=32, clock=clock)
    doomed = [Request(uid=i, prompt=prompts[i], max_new_tokens=50,
                      deadline_s=float(i + 1)) for i in range(3)]
    survivors = [Request(uid=10 + i, prompt=prompts[3 + i], max_new_tokens=4)
                 for i in range(3)]
    for a, b in zip(doomed, survivors):
        server.submit(a)
        server.submit(b)
    for _ in range(60):
        clock.t += 1.0  # every step expires the next doomed deadline
        if server.step() == 0 and not any(server._queues.values()):
            break
    assert all(r.done and r.expired for r in doomed)
    assert all(r.done and not r.expired for r in survivors)
    refs = [greedy_decode(model, params, r.prompt, 4, max_len=32)
            for r in survivors]
    for r, ref in zip(survivors, refs):
        assert r.output == ref
    assert server._active == [None, None]


# ------------------------------------------------------------------ energy
def test_bulk_energy_matches_per_token_reference(dense):
    """Dispatch-boundary (device-counted) charging == the seed's per-token
    charging, per request and per unit, with identical outputs."""
    cfg, model, params = dense
    tech = calibrate()
    prompts = _prompts(cfg, (4, 9, 6, 12))

    def serve(cls, **kw):
        policy = chip.ChipPolicy(chip.fabricated_chip("sp", tech), tech)
        server = cls(model, params, slots=2, max_len=32, chip_policy=policy,
                     **kw)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        for r in reqs:
            server.submit(r)
        for _ in range(40):
            if server.step() == 0:
                break
        return server, reqs

    ref_server, ref_reqs = serve(ReferenceServer)
    new_server, new_reqs = serve(BatchedServer)
    # drain multi-token dispatches too: same totals at coarser granularity
    bulk_server, bulk_reqs = serve(BatchedServer, dispatch_tokens=4)
    bulk_server.run(max_steps=10)
    for ref, a, b in zip(ref_reqs, new_reqs, bulk_reqs):
        assert a.output == ref.output == b.output
        assert a.energy_j == pytest.approx(ref.energy_j, rel=1e-9)
        assert b.energy_j == pytest.approx(ref.energy_j, rel=1e-9)
        for unit, e in ref.unit_energy_j.items():
            assert a.unit_energy_j[unit] == pytest.approx(e, rel=1e-9)
            assert b.unit_energy_j[unit] == pytest.approx(e, rel=1e-9)
    ref_rep = ref_server.energy_report()
    for server in (new_server, bulk_server):
        rep = server.energy_report()
        assert rep["tokens_decoded"] == ref_rep["tokens_decoded"]
        for unit, e in ref_rep["per_unit_j"].items():
            assert rep["per_unit_j"][unit] == pytest.approx(e, rel=1e-9)


# ----------------------------------------------------------- fleet routing
def test_partition_slots_proportional():
    units = chip.fabricated_chip(None, calibrate()).units
    cma = [u for u in units if u.design.style == "cma"]
    fleets = chip.partition_slots(8, cma)
    assert sorted(fleets) == sorted(u.name for u in cma)
    all_slots = [s for ids in fleets.values() for s in ids]
    assert sorted(all_slots) == list(range(8))
    assert all(len(ids) >= 1 for ids in fleets.values())
    with pytest.raises(ValueError):
        chip.partition_slots(1, cma)


def test_admission_routing_by_precision(dense):
    """SP and DP requests land on their precision's decode fleet and are
    charged on that fleet's unit."""
    cfg, model, params = dense
    tech = calibrate()
    policy = chip.ChipPolicy(chip.fabricated_chip(None, tech), tech)
    server = BatchedServer(model, params, slots=4, max_len=32,
                           chip_policy=policy)
    assert sorted(server._fleets) == ["dp_cma", "sp_cma"]
    prompts = _prompts(cfg, (4, 5, 6, 7))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=3,
                    precision="dp" if i % 2 else "sp")
            for i, p in enumerate(prompts)]
    for r in reqs:
        server.submit(r)
    server.run(max_steps=30)
    for r in reqs:
        want = "dp_cma" if r.uid % 2 else "sp_cma"
        assert r.routed_unit == want
        assert r.unit_energy_j[want] > 0
    rep = server.energy_report()
    assert rep["per_unit_j"]["sp_cma"] > 0
    assert rep["per_unit_j"]["dp_cma"] > 0
    fleets = server.fleet_report()
    assert set(fleets) == {"sp_cma", "dp_cma"}
    assert all(f["queued"] == 0 and f["active"] == 0
               for f in fleets.values())


def test_admission_routing_by_deadline_class(dense):
    """With deadline_routing on, deadline-bound traffic rides the
    latency-class (CMA) fleet and bulk traffic the throughput-class (FMA)
    fleet of the same precision."""
    cfg, model, params = dense
    tech = calibrate()
    policy = chip.ChipPolicy(chip.fabricated_chip("sp", tech), tech)
    assert [u.name for u in policy.decode_fleet_units(
        deadline_routing=True)] == ["sp_cma", "sp_fma"]
    clock = FakeClock(0.0)
    server = BatchedServer(model, params, slots=4, max_len=32,
                           chip_policy=policy, deadline_routing=True,
                           clock=clock)
    prompts = _prompts(cfg, (4, 5))
    interactive = Request(uid=0, prompt=prompts[0], max_new_tokens=3,
                          deadline_s=1e9)
    bulk = Request(uid=1, prompt=prompts[1], max_new_tokens=3)
    server.submit(interactive)
    server.submit(bulk)
    server.run(max_steps=20)
    assert interactive.routed_unit == "sp_cma"
    assert bulk.routed_unit == "sp_fma"
    assert interactive.unit_energy_j["sp_cma"] > 0
    assert bulk.unit_energy_j["sp_fma"] > 0


def test_stop_tokens_bitwise_parity_with_greedy(dense):
    """Satellite acceptance: EOS-class stop tokens freeze lanes inside the
    fused scan — per-request outputs must equal greedy_decode with the same
    stop set token for token, across dispatch-boundary positions."""
    cfg, model, params = dense
    prompts = _prompts(cfg, (3, 8, 9, 15))
    plain = [greedy_decode(model, params, p, 12, max_len=64)
             for p in prompts]
    # stop ids that actually occur mid-stream (one early, one late) so the
    # stop lands both inside a dispatch and at a dispatch boundary
    stops = (plain[0][3], plain[2][1])
    refs = [greedy_decode(model, params, p, 12, max_len=64,
                          stop_tokens=stops) for p in prompts]
    assert any(len(r) < 12 for r in refs)  # the stops really fire
    server = BatchedServer(model, params, slots=2, max_len=64,
                           dispatch_tokens=4, stop_tokens=stops)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=12)
            for i, p in enumerate(prompts)]
    for r in reqs:
        server.submit(r)
    finished = server.run(max_steps=100)
    assert sorted(r.uid for r in finished) == [0, 1, 2, 3]
    for r, ref in zip(reqs, refs):
        assert r.output == ref, (r.uid, r.output, ref)
        assert r.done and not r.expired


def test_stop_token_on_first_prefill_token(dense):
    """A prompt whose very first sampled token is a stop id finishes at
    admission without ever occupying a decode slot — and its device lane
    is freed too: later dispatches (driven here by a concurrent request)
    must not decode zombie tokens for the recycled slot."""
    cfg, model, params = dense
    p, other = _prompts(cfg, (6, 9))
    first = greedy_decode(model, params, p, 1, max_len=32)[0]
    other_ref = greedy_decode(model, params, other, 6, max_len=32)
    server = BatchedServer(model, params, slots=2, max_len=32,
                           dispatch_tokens=2, stop_tokens=(first,))
    req = Request(uid=0, prompt=p, max_new_tokens=8)
    longer = Request(uid=1, prompt=other, max_new_tokens=6)
    server.submit(req)
    server.submit(longer)
    server.run(max_steps=20)
    assert req.done and req.output == [first]
    assert longer.output == other_ref
    assert server._active == [None, None]
    # the EOS'd lane was deactivated on device at admission: every decoded
    # token is accounted to a live request, none to the zombie slot
    assert not bool(np.asarray(server._active_mask).any())
    assert server.tokens_decoded == len(req.output) + len(longer.output)


def test_admission_routing_by_accuracy_class(dense):
    """Requests carrying an accuracy SLO land on the cheapest fleet whose
    unit format meets it: loose-SLO traffic on the sub-SP (fp8) unit,
    tight-SLO traffic on the FP32 unit."""
    from helpers import make_chip_unit as unit
    from repro.core.formats import FP32, FP8_E4M3
    cfg, model, params = dense

    spec = chip.ChipSpec("tiered", (unit("decode_eco", FP8_E4M3, 1e-2, 0.5),
                                    unit("decode_gold", FP32, 1e-8, 4.0)))
    policy = chip.ChipPolicy(spec, calibrate())
    server = BatchedServer(model, params, slots=4, max_len=32,
                           chip_policy=policy,
                           accuracy_fleets=(5e-2, 1e-7))
    assert sorted(server._fleets) == ["decode_eco", "decode_gold"]
    prompts = _prompts(cfg, (4, 5, 6))
    loose = Request(uid=0, prompt=prompts[0], max_new_tokens=3,
                    accuracy_slo=5e-2)
    tight = Request(uid=1, prompt=prompts[1], max_new_tokens=3,
                    accuracy_slo=1e-7)
    dont_care = Request(uid=2, prompt=prompts[2], max_new_tokens=3)
    for r in (loose, tight, dont_care):
        server.submit(r)
    server.run(max_steps=30)
    assert loose.routed_unit == "decode_eco"
    assert tight.routed_unit == "decode_gold"
    assert dont_care.routed_unit == "decode_eco"  # class objective winner
    assert loose.unit_energy_j["decode_eco"] > 0
    assert tight.unit_energy_j["decode_gold"] > 0
    # the loose fleet's pJ/FLOP is the cheap one: same token count, less J
    assert loose.energy_j < tight.energy_j


def test_accuracy_fallback_picks_most_accurate_provisioned_fleet(dense):
    """When the chip routes an accuracy-tagged request to a unit no fleet
    was provisioned for, admission re-resolves against the provisioned
    units — most accurate available, never an arbitrary fleet."""
    from helpers import make_chip_unit as unit
    from repro.core.formats import BF16, FP32, FP8_E4M3
    cfg, model, params = dense
    spec = chip.ChipSpec("tri", (unit("decode_eco", FP8_E4M3, 1e-2, 0.5),
                                 unit("decode_mid", BF16, 1e-3, 1.0),
                                 unit("decode_gold", FP32, 1e-8, 4.0)))
    policy = chip.ChipPolicy(spec, calibrate())
    # fleets provisioned only for the loose classes: eco + mid
    server = BatchedServer(model, params, slots=4, max_len=32,
                           chip_policy=policy,
                           accuracy_fleets=(5e-2, 5e-3))
    assert sorted(server._fleets) == ["decode_eco", "decode_mid"]
    # tight request: the chip would route decode_gold (unprovisioned) —
    # admission must degrade to the most accurate *provisioned* fleet
    # (mid), not silently land on the fp8 fleet
    tight = Request(uid=0, prompt=_prompts(cfg, (4,))[0], max_new_tokens=3,
                    accuracy_slo=1e-7)
    # a mid-class request takes the cheapest fleet meeting its SLO
    mid = Request(uid=1, prompt=_prompts(cfg, (5,))[0], max_new_tokens=3,
                  accuracy_slo=5e-3)
    server.submit(tight)
    server.submit(mid)
    server.run(max_steps=20)
    assert tight.routed_unit == "decode_mid"
    assert mid.routed_unit == "decode_mid"
