"""Property-based tests (hypothesis) for the softfloat round-to-odd helpers.

An exact rational oracle (``fractions.Fraction``) independently re-derives
RNE-to-format rounding, so ``sf_fma`` (round-to-odd double-rounding
protection) and ``sf_cma`` (two explicit roundings) are checked bit-exactly
against first principles rather than against another float path.  Also:
commutativity of ``sf_add`` and idempotence of ``quantize64``.

This module is collect-ignored when hypothesis is not installed (see
tests/conftest.py); CI installs hypothesis and runs it.
"""
import fractions
import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

from repro.core import softfloat as sf
from repro.core.formats import BF16, FloatFormat
from repro.numerics import REGISTRY

# Exhaustive property sweep over the whole format ladder: minutes of wall
# clock, so it rides in the slow lane (CI fast lane runs -m "not slow").
pytestmark = pytest.mark.slow

# The whole sub-f32 transprecision ladder of the registry (satellite: the
# fp8 tiers join the suite) — every format the tuner can downshift to is
# property-tested against the exact rational oracle.
FMTS = [REGISTRY.format(n) for n in ("bf16", "fp16", "tf32",
                                     "fp8_e4m3", "fp8_e5m2")]


# ---------------------------------------------------------------------------
# Exact rational RNE oracle (mirrors quantize64's semantics: exponent
# clamped to [emin, emax] — the clamp makes the grid flush to the subnormal
# quantum — IEEE overflow to inf past max_finite).
# ---------------------------------------------------------------------------
def _rne_int(q: fractions.Fraction) -> int:
    """Round a rational to the nearest integer, ties to even."""
    fl = q.numerator // q.denominator  # floor division, exact
    rem = q - fl
    if rem > fractions.Fraction(1, 2):
        return fl + 1
    if rem < fractions.Fraction(1, 2):
        return fl
    return fl if fl % 2 == 0 else fl + 1


def rne_reference(v: fractions.Fraction, fmt: FloatFormat) -> float:
    """Exact RNE of a rational onto fmt's grid, from first principles."""
    if v == 0:
        return 0.0
    av = abs(v)
    e = math.frexp(float(av))[1] - 1  # binade estimate, then make it exact
    while fractions.Fraction(2) ** e > av:
        e -= 1
    while fractions.Fraction(2) ** (e + 1) <= av:
        e += 1
    q_exp = min(max(e, fmt.emin), fmt.emax)
    scale = fractions.Fraction(2) ** (q_exp - fmt.man_bits)
    y = _rne_int(v / scale) * scale
    if abs(y) > fractions.Fraction(fmt.max_finite):
        return math.copysign(math.inf, float(v))
    return float(y)  # exact: small-integer multiple of a power of two


def on_grid(fmt: FloatFormat):
    """Strategy for exact normal-range fmt-grid values (sign x mantissa x
    exponent).  Exponents stay inside [emin, emax] so inputs honor the
    "inputs assumed on fmt's grid" contract; *results* of mul/fma still
    exercise the overflow and subnormal-clamp branches (e.g. two FP16
    values at e=15 multiply to e~30 -> inf)."""
    return st.one_of(
        st.just(0.0),
        st.builds(
            lambda s, m, e: s * (2 ** fmt.man_bits + m) * 2.0 ** (
                e - fmt.man_bits),
            st.sampled_from([-1.0, 1.0]),
            st.integers(0, 2 ** fmt.man_bits - 1),
            st.integers(max(fmt.emin, -18), min(fmt.emax, 18))))


def _f(x):
    return float(np.float32(x))


# ---------------------------------------------------------------------------
# sf_fma / sf_cma vs the rational reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
@settings(max_examples=250, deadline=None)
@given(data=st.data())
def test_fma_matches_exact_rational_reference(fmt, data):
    a = data.draw(on_grid(fmt))
    b = data.draw(on_grid(fmt))
    c = data.draw(on_grid(fmt))
    ref = rne_reference(
        fractions.Fraction(a) * fractions.Fraction(b) + fractions.Fraction(c),
        fmt)
    ours = float(sf.sf_fma(jnp.float32(a), jnp.float32(b), jnp.float32(c),
                           fmt))
    assert ours == _f(ref), (a, b, c, ours, ref)


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
@settings(max_examples=250, deadline=None)
@given(data=st.data())
def test_cma_matches_two_rounding_reference(fmt, data):
    a = data.draw(on_grid(fmt))
    b = data.draw(on_grid(fmt))
    c = data.draw(on_grid(fmt))
    p = rne_reference(fractions.Fraction(a) * fractions.Fraction(b), fmt)
    if math.isinf(p):
        ref = p  # inf + finite addend stays inf
    else:
        ref = rne_reference(fractions.Fraction(p) + fractions.Fraction(c),
                            fmt)
    ours = float(sf.sf_cma(jnp.float32(a), jnp.float32(b), jnp.float32(c),
                           fmt))
    assert ours == _f(ref) or (math.isnan(ours) and math.isnan(ref)), \
        (a, b, c, ours, ref)


def test_fma_vs_cma_divergence_case():
    """Deterministic witness that the oracle distinguishes one rounding from
    two: the rounded product loses exactly the bits the sum needs."""
    a = 1.0 + 2.0 ** -7
    fused = float(sf.sf_fma(jnp.float32(a), jnp.float32(a),
                            jnp.float32(-1.0), BF16))
    casc = float(sf.sf_cma(jnp.float32(a), jnp.float32(a),
                           jnp.float32(-1.0), BF16))
    exact = fractions.Fraction(a) ** 2 - 1
    assert fused == rne_reference(exact, BF16)
    assert fused != casc


# ---------------------------------------------------------------------------
# Algebraic properties
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
@settings(max_examples=250, deadline=None)
@given(data=st.data())
def test_add_commutative(fmt, data):
    a = data.draw(on_grid(fmt))
    b = data.draw(on_grid(fmt))
    ab = float(sf.sf_add(jnp.float32(a), jnp.float32(b), fmt))
    ba = float(sf.sf_add(jnp.float32(b), jnp.float32(a), fmt))
    assert ab == ba or (math.isnan(ab) and math.isnan(ba))


finite_f64 = st.floats(allow_nan=False, allow_infinity=False,
                       min_value=-1e30, max_value=1e30)


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
@settings(max_examples=250, deadline=None)
@given(x=finite_f64)
def test_quantize64_idempotent(fmt, x):
    with jax.experimental.enable_x64():
        q1 = float(sf.quantize64(jnp.float64(x), fmt))
        q2 = float(sf.quantize64(jnp.float64(q1), fmt))
        assert q1 == q2  # finite input never rounds to NaN; inf == inf


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
@settings(max_examples=250, deadline=None)
@given(data=st.data())
def test_quantize64_fixes_grid_points(fmt, data):
    """Every on-grid value is its own rounding (grid points are fixed
    points), tying the input strategy to quantize64's grid definition."""
    x = data.draw(on_grid(fmt))
    with jax.experimental.enable_x64():
        assert float(sf.quantize64(jnp.float64(x), fmt)) == x
