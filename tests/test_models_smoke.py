"""Per-arch smoke tests (assignment requirement): reduced same-family config,
one forward/train step on CPU, output shapes + no NaNs; plus decode-vs-
teacher-forced consistency and full-config parameter-count sanity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, all_configs, cells, get_config
from repro.data.pipeline import for_arch, make_batch
from repro.models import LM
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import make_train_state, make_train_step

# published parameter counts (approx, for sanity bounds)
PUBLISHED_PARAMS = {
    "tinyllama-1.1b": 1.1e9,
    "starcoder2-7b": 7.2e9,
    "chatglm3-6b": 6.2e9,
    "deepseek-67b": 67e9,
    "deepseek-moe-16b": 16.4e9,
    "mixtral-8x7b": 46.7e9,
    "internvl2-1b": 0.6e9,  # LM backbone only (ViT is stubbed)
    "zamba2-1.2b": 1.2e9,
    "falcon-mamba-7b": 7.3e9,
    "musicgen-large": 3.3e9,
}


def _batch_for(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_prefix_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.frontend == "audio":
        batch = {"frame_embeds": jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32),
            "labels": batch["labels"]}
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = LM(cfg)
    batch = _batch_for(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = make_train_state(model, jax.random.key(0), opt)
    step = make_train_step(model, opt)
    # forward shapes
    logits, aux = model.apply(model_params(state), batch.get("tokens"),
                              prefix_embeds=batch.get("prefix_embeds"),
                              frame_embeds=batch.get("frame_embeds"))
    S_total = 32 + (cfg.n_prefix_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, S_total, model.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    # one train step
    state2, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed (check a 2D weight; 1D bf16 norm scales can
    # round back to their old value at lr ~1e-3)
    changed = any(
        a.ndim >= 2 and not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(state2.params)))
    assert changed


def model_params(state):
    return state.params


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forced(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    if cfg.frontend == "audio":
        return  # decode path uses token embeddings; prompt is frame stub
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)))
    kw = {}
    if cfg.frontend == "vision":
        kw["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((1, cfg.n_prefix_tokens, cfg.d_model)),
            jnp.float32)
    full, _ = model.apply(params, toks, **kw)
    prefix = cfg.n_prefix_tokens if cfg.frontend == "vision" else 0
    last, cache = model.prefill(params, toks[:, :8], max_len=12 + prefix,
                                **kw)
    tol = 0.05  # f32 + flash-block reassociation + MoE routing flips
    assert float(jnp.abs(last - full[:, -5]).max()) < tol
    for i in range(8, 12):
        lg, cache = model.decode_step(params, cache, toks[:, i:i + 1])
        assert float(jnp.abs(lg[:, 0] - full[:, i - 12]).max()) < tol


def test_full_config_param_counts():
    for arch, published in PUBLISHED_PARAMS.items():
        cfg = get_config(arch)
        ours = cfg.param_count()
        ratio = ours / published
        assert 0.6 < ratio < 1.5, f"{arch}: {ours:.3g} vs {published:.3g}"


def test_cells_long_context_rule():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        cs = cells(arch)
        if cfg.supports_long_context:
            assert "long_500k" in cs, arch
        else:
            assert "long_500k" not in cs, arch
    # exactly 33 runnable cells (40 - 7 documented skips)
    assert sum(len(cells(a)) for a in ARCH_IDS) == 33


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "zamba2-1.2b"])
def test_short_training_reduces_loss(arch):
    cfg = get_config(arch).reduced()
    model = LM(cfg)
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                      weight_decay=0.0)
    state = make_train_state(model, jax.random.key(2), opt)
    step = jax.jit(make_train_step(model, opt))
    dcfg = for_arch(cfg, seq_len=32, global_batch=8)
    losses = []
    for i in range(40):
        state, m = step(state, make_batch(dcfg, i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[::8]
