"""Attention correctness: flash vs dense reference, custom VJP gradients,
decode path, ring-cache/window semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import decode_attention, flash_attention
from repro.models.flash_vjp import flash_attention_trainable


def ref_attn(q, k, v, causal=True, window=0, kv_len=None):
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    Sk = k.shape[1]
    kr = jnp.repeat(k, G, 2)
    vr = jnp.repeat(v, G, 2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / np.sqrt(D)
    qpos = np.arange(S)
    kpos = np.arange(Sk)
    mask = np.ones((S, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))


CASES = [
    dict(S=64, Hq=4, Hkv=2, D=16, window=0),
    dict(S=100, Hq=8, Hkv=8, D=8, window=24),
    dict(S=33, Hq=6, Hkv=1, D=8, window=0),
]


@pytest.mark.parametrize("case", CASES, ids=str)
@pytest.mark.parametrize("impl", ["plain", "vjp", "triangle"])
def test_flash_matches_reference(case, impl):
    rng = np.random.default_rng(0)
    S, Hq, Hkv, D, win = (case["S"], case["Hq"], case["Hkv"], case["D"],
                          case["window"])
    q = jnp.asarray(rng.standard_normal((2, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, S, Hkv, D)), jnp.float32)
    ref = ref_attn(q, k, v, window=win)
    kw = dict(window=win, block_q=32, block_k=16)
    if impl == "plain":
        out = flash_attention(q, k, v, **kw)
    elif impl == "vjp":
        out = flash_attention_trainable(q, k, v, **kw)
    else:
        out = flash_attention(q, k, v, triangle_skip=True, **kw)
    assert float(jnp.abs(out - ref).max()) < 2e-5


def test_flash_vjp_gradients():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 48, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 48, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 48, 2, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((2, 48, 4, 8)), jnp.float32)

    def loss_ref(qkv):
        return jnp.sum(ref_attn(*qkv) * w)

    def loss_flash(qkv):
        return jnp.sum(flash_attention_trainable(
            *qkv, block_q=16, block_k=16).astype(jnp.float32) * w)

    g_ref = jax.grad(loss_ref)((q, k, v))
    g_fl = jax.grad(loss_flash)((q, k, v))
    for a, b in zip(g_ref, g_fl):
        rel = float(jnp.abs(a - b).max() / (jnp.abs(a).max() + 1e-9))
        assert rel < 1e-5, rel


def test_decode_attention_matches_reference():
    rng = np.random.default_rng(2)
    B, Smax, Hkv, Hq, D = 3, 40, 2, 6, 8
    k = jnp.asarray(rng.standard_normal((B, Smax, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Smax, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    for clen in (1, 17, 40):
        out = decode_attention(q, k, v, clen)
        # reference: attend over first clen entries only
        ref = ref_attn(q, k[:, :clen], v[:, :clen], causal=False)
        assert float(jnp.abs(out - ref).max()) < 2e-5, clen


def test_decode_attention_window():
    rng = np.random.default_rng(3)
    B, Smax, H, D = 2, 32, 2, 8
    k = jnp.asarray(rng.standard_normal((B, Smax, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Smax, H, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    clen, win = 30, 8
    out = decode_attention(q, k, v, clen, window=win)
    ref = ref_attn(q, k[:, clen - win:clen], v[:, clen - win:clen],
                   causal=False)
    assert float(jnp.abs(out - ref).max()) < 2e-5


def test_fully_masked_rows_are_zero_not_nan():
    """Window smaller than block: early rows of later q blocks can see no
    valid KV in some blocks; outputs must stay finite."""
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((1, 64, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 8)), jnp.float32)
    out = flash_attention(q, k, v, window=4, block_q=16, block_k=16)
    assert bool(jnp.isfinite(out).all())
    out2 = flash_attention_trainable(q, k, v, window=4, block_q=16,
                                     block_k=16)
    assert bool(jnp.isfinite(out2).all())
