"""Bit-exact FMA/CMA semantics vs math.fma and exactness oracles."""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import assume, given, settings, strategies as st

from repro.core import softfloat as sf
from repro.core.formats import BF16, FP16, FP32, TF32

f64s = st.floats(allow_nan=False, allow_infinity=False,
                 min_value=-1e15, max_value=1e15)


def f32(x):
    return float(np.float32(x))


@settings(max_examples=300, deadline=None)
@given(f64s, f64s, f64s)
def test_sp_fma_matches_math_fma(a, b, c):
    a, b, c = f32(a), f32(b), f32(c)
    ref = f32(math.fma(a, b, c))
    # XLA:CPU (and TPU) are DAZ/FTZ: subnormal f32 in/outputs act as zero
    assume(all(_normal_f32(v) for v in (a, b, c, ref)))
    ours = float(sf.sf_fma(jnp.float32(a), jnp.float32(b), jnp.float32(c),
                           FP32))
    assert ours == ref or (math.isnan(ours) and math.isnan(ref))


@settings(max_examples=300, deadline=None)
@given(f64s, f64s)
def test_sp_mul_add_exact(a, b):
    a, b = f32(a), f32(b)
    prod, ssum = f32(np.float32(a) * np.float32(b)), f32(np.float32(a) + np.float32(b))
    # XLA:CPU (and TPU) are DAZ/FTZ: subnormal f32 in/outputs act as zero
    assume(all(_normal_f32(v) for v in (a, b, prod, ssum)))
    assert float(sf.sf_mul(jnp.float32(a), jnp.float32(b), FP32)) == prod
    assert float(sf.sf_add(jnp.float32(a), jnp.float32(b), FP32)) == ssum


def _normal_f32(v):
    return v == 0 or abs(v) >= 2 ** -126


def _normal_range(*vals):
    # documented softfloat limitation: EFT emulation is exact except at
    # extreme over/underflow (subnormal intermediates)
    return all(v == 0 or 1e-290 < abs(v) < 1e290 for v in vals)


@settings(max_examples=200, deadline=None)
@given(f64s, f64s, f64s)
def test_dp_fma_matches_math_fma(a, b, c):
    assume(_normal_range(a * b, a * b + c))
    ours = float(sf.dp_fma(np.float64(a), np.float64(b), np.float64(c)))
    ref = math.fma(a, b, c)
    assert ours == ref or (math.isnan(ours) and math.isnan(ref))


@settings(max_examples=100, deadline=None)
@given(f64s, f64s)
def test_dp_fma_cancellation(a, b):
    # c ~ -a*b: the catastrophic-cancellation case that breaks naive
    # double-rounding emulations
    c = -(a * b) * (1 + 2 ** -50)
    assume(_normal_range(a * b, a * b + c))
    ours = float(sf.dp_fma(np.float64(a), np.float64(b), np.float64(c)))
    ref = math.fma(a, b, c)
    assert ours == ref or (math.isnan(ours) and math.isnan(ref))


def test_cma_vs_fma_rounding_counts():
    """CMA (two roundings) differs from FMA (one) exactly where the rounded
    product loses bits that matter to the sum."""
    a = jnp.float32(1.0 + 2.0 ** -7)  # product needs > 7 bits
    b = jnp.float32(1.0 + 2.0 ** -7)
    c = jnp.float32(-1.0)
    fused = float(sf.sf_fma(a, b, c, BF16))
    cascade = float(sf.sf_cma(a, b, c, BF16))
    exact = float(a) * float(b) + float(c)
    assert abs(fused - exact) <= abs(cascade - exact)


@pytest.mark.parametrize("fmt", [BF16, FP16, TF32])
def test_dot_error_ordering(fmt):
    """Forwarding (unrounded accumulator) <= fused <= cascade error, on
    average — the paper's motivation for internal forwarding [8]."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((64, 128)).astype(np.float32)
    b = rng.standard_normal((64, 128)).astype(np.float32)
    exact = np.sum(a.astype(np.float64) * b.astype(np.float64), -1)
    e_fwd = np.abs(np.asarray(sf.dot_cascade(a, b, fmt, forwarding=True),
                              np.float64) - exact).mean()
    e_fused = np.abs(np.asarray(sf.dot_fused(a, b, fmt), np.float64)
                     - exact).mean()
    e_casc = np.abs(np.asarray(sf.dot_cascade(a, b, fmt, forwarding=False),
                               np.float64) - exact).mean()
    # the paper's claim: internal forwarding (unrounded accumulator) is the
    # clear win; fused vs cascade are the same ballpark (both round the
    # accumulator every step)
    assert e_fwd < 0.5 * min(e_fused, e_casc)
    assert 0.5 < e_fused / e_casc < 2.0


def test_dot_dispatch():
    rng = np.random.default_rng(1)
    a = rng.standard_normal((8, 16)).astype(np.float32)
    b = rng.standard_normal((8, 16)).astype(np.float32)
    assert np.allclose(np.asarray(sf.dot(a, b, BF16, "fma")),
                       np.asarray(sf.dot_fused(a, b, BF16)))
    with pytest.raises(ValueError):
        sf.dot(a, b, BF16, "nope")


def test_two_sum_exact():
    rng = np.random.default_rng(2)
    with __import__("jax").experimental.enable_x64():
        a = jnp.asarray(rng.standard_normal(1000) * 1e10)
        b = jnp.asarray(rng.standard_normal(1000) * 1e-10)
        s, e = sf._two_sum(a, b)
        # s + e == a + b exactly: check via arbitrary-precision floats
        for i in range(0, 1000, 97):
            import fractions
            lhs = fractions.Fraction(float(s[i])) + fractions.Fraction(float(e[i]))
            rhs = fractions.Fraction(float(a[i])) + fractions.Fraction(float(b[i]))
            assert lhs == rhs
